"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Shape/dtype sweeps + property tests per the kernel contract: ABFT checksum
arithmetic must be bit-exact (int32 wraparound), rollback must cover every
injected above-threshold error (union policy, isolated flips).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                         # deterministic local fallback
    from _hypothesis_stub import given, settings, st

from repro.core import fault, quant
from repro.kernels import abft_matmul as ak
from repro.kernels import fault_inject as fik
from repro.kernels import ops, ref
from repro.kernels import rollback_correct as rk

SHAPES = [
    (32, 32, 32, 32, 32, 32),
    (64, 96, 128, 32, 32, 32),
    (128, 64, 64, 32, 64, 32),
    (96, 128, 96, 32, 32, 64),
    (256, 128, 128, 128, 128, 128),   # MXU-aligned production tile
    (64, 32, 64, 64, 64, 32),
]


def _rand_int8(key, shape):
    return jax.random.randint(key, shape, -127, 128, dtype=jnp.int8)


def _rand_flips(key, shape, p=0.01):
    k1, k2 = jax.random.split(key)
    hit = jax.random.uniform(k1, shape) < p
    pos = jax.random.randint(k2, shape, 0, 32, dtype=jnp.uint32)
    return jnp.where(hit, jnp.left_shift(jnp.uint32(1), pos), jnp.uint32(0))


@pytest.mark.parametrize("m,k,n,bm,bn,bk", SHAPES)
def test_abft_matmul_exact(m, k, n, bm, bn, bk):
    key = jax.random.PRNGKey(m * 7 + n)
    aq = _rand_int8(key, (m, k))
    bq = _rand_int8(jax.random.fold_in(key, 1), (k, n))
    flips = _rand_flips(jax.random.fold_in(key, 2), (m, n))
    got = ak.abft_matmul(aq, bq, flips, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.abft_matmul_ref(aq, bq, flips, bm, bn)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("m,k,n,bm,bn,bk", SHAPES[:4])
@pytest.mark.parametrize("union", [True, False])
def test_rollback_correct_matches_ref(m, k, n, bm, bn, bk, union):
    key = jax.random.PRNGKey(n)
    aq = _rand_int8(key, (m, k))
    bq = _rand_int8(jax.random.fold_in(key, 1), (k, n))
    flips = _rand_flips(jax.random.fold_in(key, 2), (m, n), p=0.02)
    c_f, ar, er, ac, ec = ref.abft_matmul_ref(aq, bq, flips, bm, bn)
    cf32 = c_f.astype(jnp.float32)
    ckpt = jax.random.normal(jax.random.fold_in(key, 3), (m, n))
    got_c, got_f = rk.rollback_correct(cf32, ckpt, ar - er, ac - ec,
                                       1 << 10, bm=bm, bn=bn, union=union,
                                       interpret=True)
    want_c, want_f = ref.rollback_correct_ref(cf32, ckpt, ar - er, ac - ec,
                                              1 << 10, bm, bn, union=union)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_f).astype(bool),
                                  np.asarray(want_f))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
@pytest.mark.parametrize("m,n", [(32, 64), (128, 128), (64, 256)])
def test_fault_inject_kernel(dtype, m, n):
    key = jax.random.PRNGKey(3)
    if dtype == jnp.float32:
        x = jax.random.normal(key, (m, n), dtype)
    else:
        x = jax.random.randint(key, (m, n), -1000, 1000, dtype=dtype)
    flips = _rand_flips(jax.random.fold_in(key, 1), (m, n), p=0.05)
    got = fik.fault_inject(x, flips, bm=32, bn=32, interpret=True)
    want_bits = jax.lax.bitcast_convert_type(x, jnp.uint32) ^ flips
    want = jax.lax.bitcast_convert_type(want_bits, dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_drift_gemm_corrects_large_errors():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (100, 70))
    w = jax.random.normal(jax.random.fold_in(key, 1), (70, 90))
    clean = x @ w
    out = ops.drift_gemm(x, w, clean, jax.random.fold_in(key, 2),
                         jnp.float32(3e-3), bm=32, bn=32, bk=32,
                         interpret=True)
    # Residual error bounded by quantization noise + sub-threshold flips:
    # threshold 2^10 on the int accumulator ~ 2^10 * sx * sw in f32.
    xq = quant.quantize(x)
    wq = quant.quantize(w, axis=1)
    bound = float((1 << 11) * xq.scale * jnp.max(wq.scale)) + 1.0
    assert float(jnp.abs(out.y - clean).max()) < bound


def test_drift_gemm_clean_when_ber_zero():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 64))
    out = ops.drift_gemm(x, w, None, key, jnp.float32(0.0),
                         bm=32, bn=32, bk=32, interpret=True)
    # no faults -> matches the quantized clean GEMM, zero flagged tiles
    y_clean, *_ = quant.quantized_matmul(x, w)
    np.testing.assert_allclose(np.asarray(out.y), np.asarray(y_clean),
                               rtol=1e-6)
    assert int(out.n_flagged_tiles) == 0


@settings(max_examples=25, deadline=None)
@given(bit=st.integers(min_value=10, max_value=31),
       row=st.integers(min_value=0, max_value=63),
       col=st.integers(min_value=0, max_value=63))
def test_single_high_flip_always_covered(bit, row, col):
    """Property: one isolated >=threshold flip is always detected & masked."""
    key = jax.random.PRNGKey(bit * 101 + row)
    aq = _rand_int8(key, (64, 32))
    bq = _rand_int8(jax.random.fold_in(key, 1), (32, 64))
    flips = jnp.zeros((64, 64), jnp.uint32).at[row, col].set(
        jnp.uint32(1) << jnp.uint32(bit))
    c_f, ar, er, ac, ec = ref.abft_matmul_ref(aq, bq, flips, 32, 32)
    _, mask_flag = ref.rollback_correct_ref(
        c_f.astype(jnp.float32), jnp.zeros((64, 64)), ar - er, ac - ec,
        1 << 10, 32, 32, union=True)
    assert bool(mask_flag[row // 32, col // 32])


@settings(max_examples=25, deadline=None)
@given(bit=st.integers(min_value=0, max_value=8),
       row=st.integers(min_value=0, max_value=63),
       col=st.integers(min_value=0, max_value=63))
def test_single_low_flip_never_flagged(bit, row, col):
    """Property: sub-threshold flips are left alone (Sec 4.1 tolerance)."""
    key = jax.random.PRNGKey(bit * 77 + col)
    aq = _rand_int8(key, (64, 32))
    bq = _rand_int8(jax.random.fold_in(key, 1), (32, 64))
    flips = jnp.zeros((64, 64), jnp.uint32).at[row, col].set(
        jnp.uint32(1) << jnp.uint32(bit))
    c_f, ar, er, ac, ec = ref.abft_matmul_ref(aq, bq, flips, 32, 32)
    corrected, flag = ref.rollback_correct_ref(
        c_f.astype(jnp.float32), jnp.zeros((64, 64)), ar - er, ac - ec,
        1 << 10, 32, 32, union=True)
    assert not bool(flag.any())
    np.testing.assert_array_equal(np.asarray(corrected),
                                  np.asarray(c_f, dtype=np.float32))
