"""Property tests for the tile-contiguous repack transform and a
brute-force cross-check of the perfmodel's DRAM row accounting.

``core.repack`` is the layout the checkpoint-offload store ships
snapshots in (serving/offload/layout.py), so the round trip must be
exact for every shape -- including non-tile-aligned ones, where the
transform pads and the inverse crops -- and every dtype the stores
carry. The DRAM row counts in ``perfmodel.dram`` price tile recovery for
the planner and the energy model; on alignment-friendly (power-of-two)
geometries they must agree exactly with enumerating the DRAM row of
every element's byte address.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_stub import given, settings, st

from repro.core import repack
from repro.perfmodel import dram


# ------------------------------------------------------------ round trip
@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 40), n=st.integers(1, 40),
       tm=st.integers(1, 9), tn=st.integers(1, 9),
       dtype=st.sampled_from(["float32", "int8", "int32", "bfloat16"]))
def test_repack_unpack_round_trip(m, n, tm, tn, dtype):
    """repack -> unpack is the identity for any shape/tile/dtype combo,
    aligned or not (padding is cropped away bit-exactly)."""
    x = jnp.arange(m * n).reshape(m, n).astype(dtype)
    xt = repack.repack(x, tm, tn)
    mt, nt = -(-m // tm), -(-n // tn)
    assert xt.shape == (mt, nt, tm * tn)
    assert xt.dtype == x.dtype
    back = repack.unpack(xt, (m, n), tm, tn)
    assert back.shape == (m, n) and back.dtype == x.dtype
    assert np.array_equal(np.asarray(back), np.asarray(x))


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 24), n=st.integers(1, 24),
       tm=st.integers(1, 8), tn=st.integers(1, 8))
def test_repack_tiles_are_contiguous_runs(m, n, tm, tn):
    """Each (ti, tj) slot of the repacked tensor is exactly the padded
    source tile flattened row-major -- the property that makes a tile
    read one contiguous run."""
    x = jnp.arange(m * n, dtype=jnp.float32).reshape(m, n)
    xp = np.asarray(repack.pad_to_tiles(x, tm, tn))
    xt = np.asarray(repack.repack(x, tm, tn))
    for ti in range(xt.shape[0]):
        for tj in range(xt.shape[1]):
            tile = xp[ti * tm:(ti + 1) * tm, tj * tn:(tj + 1) * tn]
            assert np.array_equal(xt[ti, tj], tile.reshape(-1))


def test_gather_tiles_zeroes_unflagged():
    x = jnp.arange(8 * 8, dtype=jnp.float32).reshape(8, 8)
    xt = repack.repack(x, 4, 4)
    flags = jnp.asarray([[True, False], [False, True]])
    g = np.asarray(repack.gather_tiles(xt, flags))
    assert np.array_equal(g[0], np.asarray(xt).reshape(4, -1)[0])
    assert np.all(g[1] == 0) and np.all(g[2] == 0)
    assert np.array_equal(g[3], np.asarray(xt).reshape(4, -1)[3])


# ------------------------------------------- DRAM row-count cross-check
def _brute_force_rows_rowmajor(tm, tn, n_cols, elem_bytes, row_bytes):
    """Distinct DRAM rows touched by tile (0, 0) of a row-major matrix:
    enumerate every element's byte address."""
    return len({(i * n_cols + j) * elem_bytes // row_bytes
                for i in range(tm) for j in range(tn)})


def _brute_force_rows_repacked(tm, tn, elem_bytes, row_bytes):
    """Tile 0 of a tile-contiguous layout: one run from offset 0."""
    return len({k * elem_bytes // row_bytes for k in range(tm * tn)})


@settings(max_examples=40, deadline=None)
@given(n_cols=st.sampled_from([16, 64, 256, 512, 1024, 4096]),
       tm=st.sampled_from([1, 2, 4, 8, 16, 32]),
       tn=st.sampled_from([2, 4, 8, 16]))
def test_rows_per_tile_matches_brute_force_enumeration(n_cols, tm, tn):
    """On power-of-two geometries (tiles align with DRAM rows, the regime
    the closed forms model) the perfmodel row counts equal a brute-force
    enumeration of touched rows."""
    if tn > n_cols:
        return
    eb, rb = 4, 2048
    assert dram.rows_per_tile_rowmajor(tm, tn, n_cols, eb, rb) == \
        _brute_force_rows_rowmajor(tm, tn, n_cols, eb, rb)
    assert dram.rows_per_tile_repacked(tm, tn, eb, rb) == \
        _brute_force_rows_repacked(tm, tn, eb, rb)


def test_repack_speedup_matches_paper_shape():
    """The q_proj-class Fig 13(b) geometry: a 32x32 tile in a wide
    activation matrix -- row-major pays one row per matrix row, repacked
    packs the tile into ceil(4KiB / 2KiB) = 2 rows."""
    assert dram.rows_per_tile_rowmajor(32, 32, 1152) == 32
    assert dram.rows_per_tile_repacked(32, 32) == 2
    assert dram.repack_speedup(32, 32, 1152) == pytest.approx(16.0)
