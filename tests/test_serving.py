"""Serving-engine tests: queue ordering, bucketing, cache-key hygiene,
mixed per-request operating points, BER-monitor carry-over, and one real
end-to-end compile-once run.

Logic tests inject a fake sampler factory (no jit, no model) so queue /
batcher / cache behavior is exercised in milliseconds; the end-to-end test
runs the real smoke DiT sampler and asserts on the exact JAX trace count.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dvfs
from repro.diffusion.sampler import SampleOutput
from repro.serving import DriftServeEngine, SamplerKey
from repro.serving.request import GenerationRequest, RequestQueue
from repro.serving import sharded as sharded_mod
from repro.serving.sharded import ShardedDriftServeEngine


def fake_factory(calls=None):
    """Sampler factory stub: echoes latents, advances the monitor by one
    update per batch, and (like the real jit path) fires on_trace once."""
    def factory(key: SamplerKey, model_cfg, scfg, on_trace):
        on_trace()

        def run(params, rng, latents, cond, text, monitor0):
            if calls is not None:
                calls.append(key)
            mon = dvfs.BerMonitorState(monitor0.ema_ber,
                                       monitor0.op_index,
                                       monitor0.n_updates + 1)
            return SampleOutput(latents, mon, jnp.int32(0),
                                jnp.int32(scfg.num_sample_steps))
        return run
    return factory


def make_engine(bucket=2, **kw):
    return DriftServeEngine(arch="dit-xl-512", smoke=True, bucket=bucket,
                            sampler_factory=fake_factory(kw.pop("calls",
                                                                None)),
                            **kw)


# ------------------------------------------------------------------ queue
def test_queue_fifo_and_take_matching():
    q = RequestQueue()
    ids = [q.submit(op="undervolt", seed=i) for i in range(3)]
    ids += [q.submit(op="overclock", seed=9)]
    assert ids == [0, 1, 2, 3]
    taken = q.take_matching("undervolt", lambda r: r.op, limit=2)
    assert [r.request_id for r in taken] == [0, 1]
    # non-matching request kept its place behind the remaining match
    assert [r.request_id for r in (q.peek(),)] == [2]
    assert len(q) == 2


def test_results_in_submission_order_across_groups():
    eng = make_engine(bucket=2)
    # interleaved ops force regrouping: [uv, oc, uv, oc] -> 2 batches
    for i, op in enumerate(["undervolt", "overclock"] * 2):
        eng.submit(steps=2, mode="drift", op=op, seed=i)
    results = eng.run()
    assert [r.request_id for r in results] == [0, 1, 2, 3]
    assert [r.op for r in results] == ["undervolt", "overclock"] * 2
    # same-op requests shared a batch despite interleaved submission
    assert results[0].batch_index == results[2].batch_index
    assert results[1].batch_index == results[3].batch_index


# -------------------------------------------------------------- bucketing
def test_odd_stream_padded_into_fixed_buckets():
    eng = make_engine(bucket=2)
    for i in range(5):
        eng.submit(steps=2, mode="drift", op="undervolt", seed=i)
    results = eng.run()
    assert len(results) == 5                      # every request answered
    assert eng.stats.batches == 3                 # ceil(5 / 2)
    assert eng.stats.padded_slots == 1            # one dummy slot total
    assert all(r.bucket_size == 2 for r in results)


def test_bucket_one_stream():
    eng = make_engine(bucket=1)
    for i in range(3):
        eng.submit(steps=2, mode="drift", op="undervolt", seed=i)
    assert len(eng.run()) == 3
    assert eng.stats.batches == 3
    assert eng.stats.padded_slots == 0


# ------------------------------------------------------------- cache keys
def test_cache_key_hygiene_no_recompile_on_repeat():
    calls = []
    eng = make_engine(bucket=2, calls=calls)
    for round_ in range(3):
        for i in range(2):
            eng.submit(steps=2, mode="drift", op="undervolt",
                       seed=round_ * 2 + i)
        eng.run()
    # one drift config + one clean-reference config, compiled once each
    assert eng.cache.compiles == 2
    assert eng.cache.traces == 2
    assert eng.cache.hits >= 2
    assert len({k for k in calls}) == 2


def test_distinct_configs_get_distinct_cache_entries():
    eng = make_engine(bucket=2)
    eng.submit(steps=2, mode="drift", op="undervolt", seed=0)
    eng.submit(steps=3, mode="drift", op="undervolt", seed=1)   # steps differ
    eng.submit(steps=2, mode="faulty", op="undervolt", seed=2)  # mode differs
    eng.submit(steps=2, mode="drift", op="overclock", seed=3)   # op differs
    results = eng.run()
    assert len(results) == 4
    assert eng.stats.batches == 4                 # nothing co-batchable
    # 4 serving configs + clean references for (steps=2) and (steps=3)
    assert eng.cache.compiles == 6


def test_clean_reference_cached_per_seed_batch():
    eng = make_engine(bucket=2)
    for round_ in range(2):                        # identical seed stream
        for i in range(2):
            eng.submit(steps=2, mode="drift", op="undervolt", seed=i)
        eng.run()
    assert eng.stats.clean_samples_computed == 1   # computed once...
    assert eng.stats.clean_sample_hits == 1        # ...reused on round 2


# --------------------------------------------- mixed ops + monitor state
def test_mixed_ops_one_run_and_auto_resolution():
    eng = make_engine(bucket=2)
    for i, op in enumerate(["undervolt", "overclock", "auto", "auto"]):
        eng.submit(steps=2, mode="drift", op=op, seed=i)
    results = eng.run()
    ops = [r.op for r in results]
    assert ops[0] == "undervolt" and ops[1] == "overclock"
    # fresh monitor: ladder index 0 -> most aggressive point
    assert ops[2] == ops[3] == dvfs.OP_LADDER[0].name
    # auto resolves to the same SamplerKey as the explicit undervolt
    # request, so the first auto request co-batches with it
    assert results[2].batch_index == results[0].batch_index


def test_monitor_carries_over_between_batches():
    eng = make_engine(bucket=1)
    for i in range(4):
        eng.submit(steps=2, mode="drift", op="undervolt", seed=i)
    eng.run()
    # fake sampler bumps n_updates once per batch, and the engine feeds each
    # batch the previous batch's monitor state
    assert int(eng.monitor.n_updates) == 4
    eng.submit(steps=2, mode="drift", op="undervolt", seed=9)
    eng.run()
    assert int(eng.monitor.n_updates) == 5         # persists across run()s


def test_clean_mode_requests_do_not_feed_monitor():
    eng = make_engine(bucket=1)
    eng.submit(steps=2, mode="clean", op="nominal", seed=0)
    eng.run()
    assert int(eng.monitor.n_updates) == 0


# --------------------------------------------- single-device degradation
def test_make_engine_falls_back_on_one_device(monkeypatch):
    """With nothing to shard over, the factory must return the plain
    single-device engine (same class PR 1 shipped), not a mesh wrapper."""
    monkeypatch.setattr(sharded_mod.jax, "device_count", lambda: 1)
    eng = sharded_mod.make_engine(bucket=2)
    assert type(eng) is DriftServeEngine
    # plain-engine cache keys carry no mesh placement
    eng.submit(steps=2, mode="drift", op="undervolt", seed=0)
    mb = eng.batcher.next_batch(eng.queue, eng._resolve_op)
    assert mb.key.mesh_shape == () and mb.key.batch_spec == ""


def test_make_engine_falls_back_on_size_one_mesh():
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    eng = sharded_mod.make_engine(mesh=mesh, bucket=2)
    assert type(eng) is DriftServeEngine
    assert not isinstance(eng, ShardedDriftServeEngine)


# ------------------------------------------------------------ end-to-end
@pytest.mark.slow
def test_end_to_end_real_sampler_compiles_once_per_config():
    eng = DriftServeEngine(arch="dit-xl-512", smoke=True, bucket=2)
    ops = ["undervolt", "overclock"]
    for i in range(4):
        eng.submit(steps=3, mode="drift", op=ops[i % 2], seed=i)
    results = eng.run()
    assert len(results) == 4
    # 2 drift configs + 1 shared clean-reference config, each traced once
    assert eng.cache.traces == 3
    assert eng.stats.batches == 2

    # second identical round: cache hits only, zero new traces
    for i in range(4):
        eng.submit(steps=3, mode="drift", op=ops[i % 2], seed=i)
    results += eng.run()
    assert eng.cache.traces == 3
    # round 2: both drift fns hit; clean refs short-circuit at the sample
    # cache, never reaching the compiled-fn cache
    assert eng.cache.hits >= 3
    assert eng.stats.clean_sample_hits == 2

    # monitor saw every drift batch (3 steps x 4 batches)
    assert int(eng.monitor.n_updates) == 12

    for r in results:
        assert r.lpips_vs_clean >= 0.0
        assert r.psnr_vs_clean_db > 20.0           # DRIFT stays near clean
        assert r.energy_j > 0.0 and r.latency_s > 0.0
        assert r.baseline_energy_j > 0.0
        assert r.n_model_evals == 3
    # undervolt saves energy vs overclock's speed mode at equal steps
    uv = [r for r in results if r.op == "undervolt"]
    oc = [r for r in results if r.op == "overclock"]
    assert uv[0].energy_j < oc[0].energy_j
    assert oc[0].latency_s < uv[0].latency_s
